"""Iteration-level continuous batching (Orca-style) for LLM serving.

Each global step the scheduler:

1. **admits** waiting requests into the running batch while there is room
   (``max_batch``) — requests queue FIFO from their Poisson arrival times;
2. assigns every running request one unit of work: a **prefill chunk**
   (``prefill_chunk`` tokens, whole prompt by default, bounded by the step's
   ``max_step_tokens`` token budget — decode tokens are budgeted first) or
   one **decode token**;
3. **evicts** requests whose decode completed, freeing their KV pages.

The scheduler is pure policy: it never touches the memory system.  The
lowering (``repro.serve.lower``) drives it step by step, converts each
:class:`StepPlan` into bank-level events, and feeds the *simulated* step
duration back into the clock — that feedback (queueing delays push arrivals
into deeper backlogs) is what makes the serving loop closed.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass
class RequestState:
    """One request's lifecycle through the batch."""

    rid: int
    arrival_ns: float
    prompt: int
    decode: int
    prefilled: int = 0
    decoded: int = 0
    admitted_ns: float = math.nan
    first_token_ns: float = math.nan
    finish_ns: float = math.nan

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= self.prompt

    @property
    def done(self) -> bool:
        return self.prefill_done and self.decoded >= self.decode


@dataclasses.dataclass(frozen=True)
class ServeEngineConfig:
    """Knobs of the continuous-batching engine (scheduler + KV paging)."""

    max_batch: int = 16  # running-batch cap (iteration-level admission)
    max_step_tokens: int = 4096  # per-step token budget (decode first)
    prefill_chunk: int | None = None  # tokens per prefill step; None = whole prompt
    page_tokens: int = 16  # tokens per KV page (all layers)
    kv_reserve_frac: float = 1.0  # fraction of the GLB usable for KV pages
    headroom: float = 1.15  # decode cadence over the weight-stream floor
    token_interval_ns: float | None = None  # explicit decode cadence floor

    def __post_init__(self):
        if self.max_batch <= 0:
            raise ValueError("max_batch must be positive")
        if self.max_step_tokens < self.max_batch:
            raise ValueError("max_step_tokens must be >= max_batch "
                             "(each decode slot costs one token)")
        if self.page_tokens <= 0:
            raise ValueError("page_tokens must be positive")


@dataclasses.dataclass
class StepPlan:
    """Work assigned to one global step.

    The block-batched lowering consumes the plan as arrays (one event block
    per traffic class across all requests), so the per-class request id /
    context columns are materialized once here and cached.
    """

    t_start_ns: float
    prefill: list  # [(RequestState, n_tokens)]
    decode: list  # [RequestState] — one token each
    _cols: tuple | None = dataclasses.field(default=None, repr=False)

    @property
    def empty(self) -> bool:
        return not self.prefill and not self.decode

    @property
    def decode_arrays(self) -> tuple:
        """``(rids, ctx)`` int64 columns over the decode batch; ``ctx`` is
        the context length read by this step's token (``prompt + decoded``,
        evaluated before the step commits)."""
        if self._cols is None:
            n = len(self.decode)
            self._cols = (
                np.fromiter((r.rid for r in self.decode), np.int64, n),
                np.fromiter((r.prompt + r.decoded for r in self.decode),
                            np.int64, n),
            )
        return self._cols


class ContinuousBatchScheduler:
    def __init__(self, arrivals_ns, prompts, decodes, cfg: ServeEngineConfig):
        self.cfg = cfg
        self.requests = [
            RequestState(rid=i, arrival_ns=float(a), prompt=int(p), decode=int(d))
            for i, (a, p, d) in enumerate(zip(arrivals_ns, prompts, decodes))
        ]
        self.requests.sort(key=lambda r: r.arrival_ns)
        self._next = 0
        self.active: list[RequestState] = []
        self.finished: list[RequestState] = []

    @property
    def done(self) -> bool:
        return self._next >= len(self.requests) and not self.active

    def backlog(self) -> int:
        """Requests not yet finished: running batch plus waiting queue."""
        return len(self.active) + (len(self.requests) - self._next)

    def add_request(self, r: RequestState) -> None:
        """Route one request into the waiting queue (fleet front-end).

        The unconsumed tail stays sorted by arrival so ``plan_step`` admits
        in arrival order; routing in global-arrival order makes the insert a
        plain append, which keeps a 1-replica fleet's queue identical to the
        up-front constructor's.
        """
        i = len(self.requests)
        while i > self._next and self.requests[i - 1].arrival_ns > r.arrival_ns:
            i -= 1
        self.requests.insert(i, r)

    def next_arrival_ns(self) -> float:
        if self._next >= len(self.requests):
            return math.inf
        return self.requests[self._next].arrival_ns

    def plan_step(self, now_ns: float) -> StepPlan:
        """Admit arrivals, then split the token budget over the batch."""
        while (
            self._next < len(self.requests)
            and len(self.active) < self.cfg.max_batch
            and self.requests[self._next].arrival_ns <= now_ns
        ):
            r = self.requests[self._next]
            r.admitted_ns = now_ns
            self.active.append(r)
            self._next += 1

        decode = [r for r in self.active if r.prefill_done]
        budget = self.cfg.max_step_tokens - len(decode)
        prefill: list = []
        for r in self.active:
            if r.prefill_done:
                continue
            chunk = min(
                self.cfg.prefill_chunk or r.prompt,
                r.prompt - r.prefilled,
                max(0, budget),
            )
            if chunk > 0:
                prefill.append((r, chunk))
                budget -= chunk
        if self.active and not decode and not prefill:
            # Budget starvation guard: a step must always make progress.
            r = next(r for r in self.active if not r.prefill_done)
            prefill.append((r, 1))
        return StepPlan(t_start_ns=now_ns, prefill=prefill, decode=decode)

    def commit_step(self, plan: StepPlan, t_end_ns: float) -> list[RequestState]:
        """Apply the step's outcome at simulated time ``t_end_ns``; returns
        the requests that completed (their KV can be freed)."""
        for r, toks in plan.prefill:
            r.prefilled += toks
        newly_finished = []
        for r in plan.decode:
            r.decoded += 1
            if math.isnan(r.first_token_ns):
                r.first_token_ns = t_end_ns
            if r.done:
                r.finish_ns = t_end_ns
                newly_finished.append(r)
        for r in newly_finished:
            self.active.remove(r)
            self.finished.append(r)
        return newly_finished
