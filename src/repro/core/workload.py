"""Workload descriptors for the paper's *Memory and Compute Model*.

The paper profiles DL workloads as ordered lists of layers, where each layer
carries the byte sizes of its ifmap (I), ofmap (O) and weights (W) plus the
dataflow-relevant dimensions (kernel/feature-map sizes for Conv layers,
``K x M @ M x N`` operand dims for GEMM/FC layers).  Algorithms 1 and 2
consume these descriptors together with a Global Buffer (GLB) capacity to
produce DRAM/GLB access counts; Section III-A consumes them to produce
required read/write bandwidths.

Everything here is plain Python (no JAX) — this is the analytical substrate.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

MB = 1024 * 1024


# ---------------------------------------------------------------------------
# Layer descriptors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """Convolution layer (paper Table I nomenclature)."""

    name: str
    k_h: int
    k_w: int
    if_h: int
    if_w: int
    of_h: int
    of_w: int
    n_ich: int
    n_och: int
    stride: int = 1

    def ifmap_bytes(self, batch: int, d_w: int) -> float:
        return batch * self.n_ich * self.if_h * self.if_w * d_w

    def ofmap_bytes(self, batch: int, d_w: int) -> float:
        return batch * self.n_och * self.of_h * self.of_w * d_w

    def weight_bytes(self, d_w: int) -> float:
        return self.k_h * self.k_w * self.n_ich * self.n_och * d_w

    def macs(self, batch: int) -> float:
        return (
            batch
            * self.n_och
            * self.of_h
            * self.of_w
            * self.n_ich
            * self.k_h
            * self.k_w
        )


@dataclasses.dataclass(frozen=True)
class GemmLayer:
    """FC/GEMM layer: input ``K x M`` @ weight ``M x N`` -> output ``K x N``.

    ``K`` is the paper's streaming dimension (batch*seq for transformers).
    """

    name: str
    K: int
    M: int
    N: int
    # Weight reuse across the batch: embedding/attention "weights" that are
    # activations (e.g. K^T in Q@K^T) have ``weights_are_activations=True`` so
    # Algorithms 1/2 treat them as per-sample data, not parameters.
    weights_are_activations: bool = False

    def ifmap_bytes(self, batch: int, d_w: int) -> float:
        return batch * self.K * self.M * d_w

    def ofmap_bytes(self, batch: int, d_w: int) -> float:
        return batch * self.K * self.N * d_w

    def weight_bytes(self, d_w: int, batch: int = 1) -> float:
        mult = batch if self.weights_are_activations else 1
        return mult * self.M * self.N * d_w

    def macs(self, batch: int) -> float:
        return batch * self.K * self.M * self.N


@dataclasses.dataclass(frozen=True)
class SoftmaxLayer:
    """Softmax over an ``rows x cols`` attention-filter matrix (SFU op)."""

    name: str
    rows: int
    cols: int

    def ifmap_bytes(self, batch: int, d_w: int) -> float:
        return batch * self.rows * self.cols * d_w

    def ofmap_bytes(self, batch: int, d_w: int) -> float:
        return batch * self.rows * self.cols * d_w

    def weight_bytes(self, d_w: int) -> float:
        return 0.0

    def macs(self, batch: int) -> float:
        # exp + sum + div ~ 3 ops per element; counted as "ops", not MACs.
        return 3 * batch * self.rows * self.cols


@dataclasses.dataclass(frozen=True)
class StreamingLayer:
    """Attention-free streaming op (SSM scan, norm, activation, conv1d).

    TPU adaptation for architectures the paper's Conv/GEMM taxonomy does not
    cover (Mamba-2 SSD, elementwise).  ``flops_per_byte`` is its operational
    intensity; I/O/W sizes feed the access-count model unchanged.
    """

    name: str
    in_bytes_per_sample: float
    out_bytes_per_sample: float
    state_bytes: float = 0.0
    flops_per_byte: float = 2.0

    def ifmap_bytes(self, batch: int, d_w: int) -> float:  # d_w already folded
        return batch * self.in_bytes_per_sample

    def ofmap_bytes(self, batch: int, d_w: int) -> float:
        return batch * self.out_bytes_per_sample

    def weight_bytes(self, d_w: int) -> float:
        return self.state_bytes

    def macs(self, batch: int) -> float:
        return self.flops_per_byte * batch * self.in_bytes_per_sample / 2


Layer = ConvLayer | GemmLayer | SoftmaxLayer | StreamingLayer


@dataclasses.dataclass(frozen=True)
class Workload:
    """An ordered DNN workload: what Algorithms 1/2 walk over."""

    name: str
    layers: tuple[Layer, ...]
    domain: str  # "cv" | "nlp" | "lm" | "ssm" | ...

    def entity_sizes_mb(self, batch: int, d_w: int) -> list[tuple[float, float, float]]:
        """Per-layer (I, O, W) sizes in MB — the paper's Table III entities."""
        out = []
        for l in self.layers:
            out.append(
                (
                    l.ifmap_bytes(batch, d_w) / MB,
                    l.ofmap_bytes(batch, d_w) / MB,
                    (
                        l.weight_bytes(d_w, batch)
                        if isinstance(l, GemmLayer)
                        else l.weight_bytes(d_w)
                    )
                    / MB,
                )
            )
        return out

    def total_macs(self, batch: int) -> float:
        return sum(l.macs(batch) for l in self.layers)

    def total_weight_mb(self, d_w: int) -> float:
        return sum(
            (l.weight_bytes(d_w, 1) if isinstance(l, GemmLayer) else l.weight_bytes(d_w))
            for l in self.layers
        ) / MB


# ---------------------------------------------------------------------------
# CV model zoo (paper Fig. 2 / Fig. 7 benchmarks)
# ---------------------------------------------------------------------------


def _conv(name, c_in, c_out, k, if_hw, stride=1) -> ConvLayer:
    of_hw = max(1, if_hw // stride)
    return ConvLayer(
        name=name,
        k_h=k,
        k_w=k,
        if_h=if_hw,
        if_w=if_hw,
        of_h=of_hw,
        of_w=of_hw,
        n_ich=c_in,
        n_och=c_out,
        stride=stride,
    )


def _resnet(name: str, block_counts: Sequence[int], bottleneck: bool) -> Workload:
    """ResNet-18/34/50/101/152 layer graphs (He et al. 2016)."""
    layers: list[Layer] = [_conv("conv1", 3, 64, 7, 224, stride=2)]
    hw = 56
    c_in = 64
    stage_width = [64, 128, 256, 512]
    for stage, (n_blocks, width) in enumerate(zip(block_counts, stage_width)):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            if_hw = hw * (stride)  # ifmap of the stage's first block is larger
            if bottleneck:
                c_out = width * 4
                layers += [
                    _conv(f"s{stage}b{b}_1x1a", c_in, width, 1, if_hw, stride),
                    _conv(f"s{stage}b{b}_3x3", width, width, 3, hw),
                    _conv(f"s{stage}b{b}_1x1b", width, c_out, 1, hw),
                ]
            else:
                c_out = width
                layers += [
                    _conv(f"s{stage}b{b}_3x3a", c_in, width, 3, if_hw, stride),
                    _conv(f"s{stage}b{b}_3x3b", width, c_out, 3, hw),
                ]
            c_in = c_out
        if stage < 3:
            hw //= 2
    layers.append(GemmLayer("fc", K=1, M=c_in, N=1000))
    return Workload(name=name, layers=tuple(layers), domain="cv")


def _vgg16() -> Workload:
    cfg = [
        (3, 64, 224), (64, 64, 224),
        (64, 128, 112), (128, 128, 112),
        (128, 256, 56), (256, 256, 56), (256, 256, 56),
        (256, 512, 28), (512, 512, 28), (512, 512, 28),
        (512, 512, 14), (512, 512, 14), (512, 512, 14),
    ]
    layers: list[Layer] = [
        _conv(f"conv{i}", ci, co, 3, hw) for i, (ci, co, hw) in enumerate(cfg)
    ]
    layers += [
        GemmLayer("fc1", K=1, M=512 * 7 * 7, N=4096),
        GemmLayer("fc2", K=1, M=4096, N=4096),
        GemmLayer("fc3", K=1, M=4096, N=1000),
    ]
    return Workload("vgg16", tuple(layers), "cv")


def _alexnet() -> Workload:
    layers: list[Layer] = [
        ConvLayer("conv1", 11, 11, 227, 227, 55, 55, 3, 96, 4),
        ConvLayer("conv2", 5, 5, 27, 27, 27, 27, 96, 256),
        ConvLayer("conv3", 3, 3, 13, 13, 13, 13, 256, 384),
        ConvLayer("conv4", 3, 3, 13, 13, 13, 13, 384, 384),
        ConvLayer("conv5", 3, 3, 13, 13, 13, 13, 384, 256),
        GemmLayer("fc1", K=1, M=256 * 6 * 6, N=4096),
        GemmLayer("fc2", K=1, M=4096, N=4096),
        GemmLayer("fc3", K=1, M=4096, N=1000),
    ]
    return Workload("alexnet", tuple(layers), "cv")


def _squeezenet() -> Workload:
    # Fire modules: squeeze 1x1 then expand 1x1 + 3x3.
    fire_cfg = [  # (c_in, squeeze, expand, hw)
        (96, 16, 64, 55), (128, 16, 64, 55), (128, 32, 128, 55),
        (256, 32, 128, 27), (256, 48, 192, 27), (384, 48, 192, 27),
        (384, 64, 256, 27), (512, 64, 256, 13),
    ]
    layers: list[Layer] = [_conv("conv1", 3, 96, 7, 111, stride=2)]
    for i, (ci, sq, ex, hw) in enumerate(fire_cfg):
        layers += [
            _conv(f"fire{i}_sq1x1", ci, sq, 1, hw),
            _conv(f"fire{i}_ex1x1", sq, ex, 1, hw),
            _conv(f"fire{i}_ex3x3", sq, ex, 3, hw),
        ]
    layers.append(_conv("conv10", 512, 1000, 1, 13))
    return Workload("squeezenet", tuple(layers), "cv")


def _mobilenet_v2() -> Workload:
    # (t expansion, c_out, n repeats, stride, hw_in)
    cfg = [
        (1, 16, 1, 1, 112), (6, 24, 2, 2, 112), (6, 32, 3, 2, 56),
        (6, 64, 4, 2, 28), (6, 96, 3, 1, 14), (6, 160, 3, 2, 14),
        (6, 320, 1, 1, 7),
    ]
    layers: list[Layer] = [_conv("conv1", 3, 32, 3, 224, 2)]
    c_in = 32
    for i, (t, c, n, s, hw) in enumerate(cfg):
        for j in range(n):
            stride = s if j == 0 else 1
            hidden = c_in * t
            hw_out = hw // stride if j == 0 else hw // s
            hw_cur = hw if j == 0 else hw // s
            if t != 1:
                layers.append(_conv(f"ir{i}_{j}_expand", c_in, hidden, 1, hw_cur))
            layers.append(_conv(f"ir{i}_{j}_dw", 1, hidden, 3, hw_cur, stride))
            layers.append(_conv(f"ir{i}_{j}_project", hidden, c, 1, hw_out))
            c_in = c
    layers.append(_conv("conv_last", 320, 1280, 1, 7))
    layers.append(GemmLayer("fc", K=1, M=1280, N=1000))
    return Workload("mobilenet_v2", tuple(layers), "cv")


def _densenet121() -> Workload:
    layers: list[Layer] = [_conv("conv1", 3, 64, 7, 224, 2)]
    c = 64
    growth = 32
    hw = 56
    for stage, n_blocks in enumerate([6, 12, 24, 16]):
        for b in range(n_blocks):
            layers.append(_conv(f"d{stage}b{b}_1x1", c, 4 * growth, 1, hw))
            layers.append(_conv(f"d{stage}b{b}_3x3", 4 * growth, growth, 3, hw))
            c += growth
        if stage < 3:
            layers.append(_conv(f"t{stage}_1x1", c, c // 2, 1, hw))
            c //= 2
            hw //= 2
    layers.append(GemmLayer("fc", K=1, M=c, N=1000))
    return Workload("densenet121", tuple(layers), "cv")


def _googlenet() -> Workload:
    # Inception v1 with representative inception branches flattened.
    incep = [  # hw, c_in, (1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj)
        (28, 192, (64, 96, 128, 16, 32, 32)),
        (28, 256, (128, 128, 192, 32, 96, 64)),
        (14, 480, (192, 96, 208, 16, 48, 64)),
        (14, 512, (160, 112, 224, 24, 64, 64)),
        (14, 512, (128, 128, 256, 24, 64, 64)),
        (14, 512, (112, 144, 288, 32, 64, 64)),
        (14, 528, (256, 160, 320, 32, 128, 128)),
        (7, 832, (256, 160, 320, 32, 128, 128)),
        (7, 832, (384, 192, 384, 48, 128, 128)),
    ]
    layers: list[Layer] = [
        _conv("conv1", 3, 64, 7, 224, 2),
        _conv("conv2a", 64, 64, 1, 56),
        _conv("conv2b", 64, 192, 3, 56),
    ]
    for i, (hw, ci, (b1, r3, b3, r5, b5, pp)) in enumerate(incep):
        layers += [
            _conv(f"i{i}_1x1", ci, b1, 1, hw),
            _conv(f"i{i}_3x3r", ci, r3, 1, hw),
            _conv(f"i{i}_3x3", r3, b3, 3, hw),
            _conv(f"i{i}_5x5r", ci, r5, 1, hw),
            _conv(f"i{i}_5x5", r5, b5, 5, hw),
            _conv(f"i{i}_pp", ci, pp, 1, hw),
        ]
    layers.append(GemmLayer("fc", K=1, M=1024, N=1000))
    return Workload("googlenet", tuple(layers), "cv")


def _efficientnet_b0() -> Workload:
    cfg = [  # (expand, c_out, n, k, stride, hw)
        (1, 16, 1, 3, 1, 112), (6, 24, 2, 3, 2, 112), (6, 40, 2, 5, 2, 56),
        (6, 80, 3, 3, 2, 28), (6, 112, 3, 5, 1, 14), (6, 192, 4, 5, 2, 14),
        (6, 320, 1, 3, 1, 7),
    ]
    layers: list[Layer] = [_conv("stem", 3, 32, 3, 224, 2)]
    c_in = 32
    for i, (t, c, n, k, s, hw) in enumerate(cfg):
        for j in range(n):
            stride = s if j == 0 else 1
            hw_cur = hw if j == 0 else hw // s
            hidden = c_in * t
            if t != 1:
                layers.append(_conv(f"mb{i}_{j}_exp", c_in, hidden, 1, hw_cur))
            layers.append(
                _conv(f"mb{i}_{j}_dw", 1, hidden, k, hw_cur, stride)
            )
            layers.append(_conv(f"mb{i}_{j}_proj", hidden, c, 1, hw_cur // stride))
            c_in = c
    layers.append(_conv("head", 320, 1280, 1, 7))
    layers.append(GemmLayer("fc", K=1, M=1280, N=1000))
    return Workload("efficientnet_b0", tuple(layers), "cv")


def cv_model_zoo() -> dict[str, Workload]:
    return {
        w.name: w
        for w in [
            _resnet("resnet18", [2, 2, 2, 2], bottleneck=False),
            _resnet("resnet34", [3, 4, 6, 3], bottleneck=False),
            _resnet("resnet50", [3, 4, 6, 3], bottleneck=True),
            _resnet("resnet101", [3, 4, 23, 3], bottleneck=True),
            _resnet("resnet152", [3, 8, 36, 3], bottleneck=True),
            _vgg16(),
            _alexnet(),
            _squeezenet(),
            _mobilenet_v2(),
            _densenet121(),
            _googlenet(),
            _efficientnet_b0(),
        ]
    }


# ---------------------------------------------------------------------------
# NLP model zoo (paper Table V)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NLPModelSpec:
    name: str
    enc_layers: int
    dec_layers: int
    heads: int
    d_model: int  # N_em
    d_ff: int
    seq_len: int  # N_sql
    vocab: int


# Table V of the paper, verbatim.
NLP_TABLE_V: tuple[NLPModelSpec, ...] = (
    NLPModelSpec("transformer", 12, 6, 8, 512, 2048, 1024, 37000),
    NLPModelSpec("bert", 12, 0, 12, 768, 3072, 512, 30522),
    NLPModelSpec("distilbert", 6, 0, 12, 768, 3072, 512, 30522),
    NLPModelSpec("mobilebert", 24, 0, 4, 128, 512, 512, 30522),
    NLPModelSpec("squeezebert", 12, 0, 12, 768, 3072, 512, 30522),
    NLPModelSpec("visualbert", 12, 0, 12, 512, 3072, 512, 30522),
    NLPModelSpec("gpt", 0, 12, 12, 768, 2048, 512, 40478),
    NLPModelSpec("gpt2", 0, 12, 12, 768, 2048, 1024, 50257),
    NLPModelSpec("gpt3", 0, 96, 96, 12288, 49152, 2048, 50257),
    NLPModelSpec("gpt_neo", 0, 24, 16, 2048, 8192, 2048, 50257),
    NLPModelSpec("gpt_j", 0, 28, 16, 4096, 16384, 2048, 50400),
)


def transformer_block_layers(
    prefix: str,
    seq: int,
    d_model: int,
    heads: int,
    d_ff: int,
    kv_heads: int | None = None,
    cross_seq: int | None = None,
) -> list[Layer]:
    """GEMM/softmax decomposition of one transformer block (paper Fig. 3)."""
    kv_heads = kv_heads if kv_heads is not None else heads
    d_head = d_model // heads
    kv_dim = kv_heads * d_head
    layers: list[Layer] = [
        GemmLayer(f"{prefix}_q", K=seq, M=d_model, N=d_model),
        GemmLayer(f"{prefix}_k", K=seq, M=d_model, N=kv_dim),
        GemmLayer(f"{prefix}_v", K=seq, M=d_model, N=kv_dim),
        # attention score GEMM: per-head Q(seq x d_head) @ K^T(d_head x seq),
        # modelled as a single GEMM with activation "weights".
        GemmLayer(
            f"{prefix}_qkT", K=heads * seq, M=d_head, N=seq, weights_are_activations=True
        ),
        SoftmaxLayer(f"{prefix}_softmax", rows=heads * seq, cols=seq),
        GemmLayer(
            f"{prefix}_av", K=heads * seq, M=seq, N=d_head, weights_are_activations=True
        ),
        GemmLayer(f"{prefix}_o", K=seq, M=d_model, N=d_model),
    ]
    if cross_seq is not None:
        layers += [
            GemmLayer(f"{prefix}_xq", K=seq, M=d_model, N=d_model),
            GemmLayer(f"{prefix}_xk", K=cross_seq, M=d_model, N=kv_dim),
            GemmLayer(f"{prefix}_xv", K=cross_seq, M=d_model, N=kv_dim),
            GemmLayer(
                f"{prefix}_xqkT",
                K=heads * seq,
                M=d_head,
                N=cross_seq,
                weights_are_activations=True,
            ),
            SoftmaxLayer(f"{prefix}_xsoftmax", rows=heads * seq, cols=cross_seq),
            GemmLayer(
                f"{prefix}_xav",
                K=heads * seq,
                M=cross_seq,
                N=d_head,
                weights_are_activations=True,
            ),
            GemmLayer(f"{prefix}_xo", K=seq, M=d_model, N=d_model),
        ]
    layers += [
        GemmLayer(f"{prefix}_ffn_up", K=seq, M=d_model, N=d_ff),
        GemmLayer(f"{prefix}_ffn_down", K=seq, M=d_ff, N=d_model),
    ]
    return layers


def nlp_workload(spec: NLPModelSpec) -> Workload:
    layers: list[Layer] = [
        # Embedding lookup modelled as a streaming gather.
        StreamingLayer(
            "embedding",
            in_bytes_per_sample=spec.seq_len * 4.0,
            out_bytes_per_sample=spec.seq_len * spec.d_model * 4.0,
            state_bytes=spec.vocab * spec.d_model * 4.0,
        )
    ]
    for i in range(spec.enc_layers):
        layers += transformer_block_layers(
            f"enc{i}", spec.seq_len, spec.d_model, spec.heads, spec.d_ff
        )
    for i in range(spec.dec_layers):
        layers += transformer_block_layers(
            f"dec{i}",
            spec.seq_len,
            spec.d_model,
            spec.heads,
            spec.d_ff,
            cross_seq=spec.seq_len if spec.enc_layers else None,
        )
    layers.append(GemmLayer("lm_head", K=spec.seq_len, M=spec.d_model, N=spec.vocab))
    return Workload(spec.name, tuple(layers), "nlp")


def nlp_model_zoo() -> dict[str, Workload]:
    return {s.name: nlp_workload(s) for s in NLP_TABLE_V}
