"""Core: the paper's STCO/DTCO memory-system co-design, in analytical form.

Submodules:
  workload       layer-graph workload descriptors + CV/NLP model zoos
  bandwidth      Section III-A bandwidth expressions (Eqs. 1-8, Table II)
  access_counts  Algorithms 1 & 2 (DRAM/GLB access counts)
  dtco           Section IV SOT-MRAM device physics + DTCO optimizer
  memory_system  array-level PPA models (SRAM / SOT / DTCO-opt SOT) + HBM3
  evaluate       system-level energy/latency/area (Figs. 9-12, 18, 19)
  stco           the closed STCO<->DTCO loop (Fig. 1)
  vmem_planner   TPU adaptation: BlockSpec tiling + remat planning
"""

from repro.core import (  # noqa: F401
    access_counts,
    bandwidth,
    dtco,
    evaluate,
    memory_system,
    stco,
    vmem_planner,
    workload,
)
