"""Algorithms 1 & 2 — DRAM & GLB access counts at inference and training.

The pseudocode in the paper's PDF is partially OCR-garbled; this module
reconstructs it from the prose of Section III-B, which specifies every case:

Inference (Algorithm 1), per layer ``i`` with entity sizes I/O/W in MB:
  * GLB reads come from the ifmap each layer (weights bypass the GLB through
    the double-buffered SRAM); GLB writes come from the ofmap (plus the
    initial input for layer 1).
  * Layer 1 must load inputs and weights from DRAM; if ``I+W`` exceeds the
    GLB the spilled portion is fetched twice.
  * For later layers, if the previous ofmap fit in the GLB it serves as the
    next ifmap (no DRAM ifmap reads — only weights); otherwise the ifmap and
    weights stream from DRAM with a spill penalty.
  * Only the last ofmap must be written back; intermediate ofmaps write
    their spilled portion (``O - GLB``) only.

Training (Algorithm 2): forward behaves like inference unless the cumulative
working set (all entities of layers ``1..i``, forward + backward) fits in the
GLB, in which case DRAM sees only the algorithmic minimum (layer-1 ifmap +
all weights in; last ofmap + updated weights out).  The backward pass reads/
writes gradient entities from DRAM only when they exceed the GLB.  GLB
action counts per layer follow the prose exactly: ifmap read 2x + upstream
gradient 1x (=> ``3*I``), ofmap read 1x, weights read 5x, ifmap/ofmap
written 2x, weights written 3x.
"""

from __future__ import annotations

import dataclasses

from repro.core.workload import Workload


@dataclasses.dataclass(frozen=True)
class MemoryParams:
    glb_mb: float = 64.0
    mbpa_dram: float = 64 / 1024 / 1024  # MB fetched per DRAM access (64B burst)
    mbpa_glb: float = 256 / 1024 / 1024  # MB per GLB access (256B bus)
    # Fraction of sequential backward-pass spill traffic whose latency the
    # double-buffered SRAM hides behind compute (Section III-B).
    prefetch_hidden_frac: float = 0.75


@dataclasses.dataclass
class AccessCounts:
    """DRAM/GLB access counts.

    Weight traffic is tracked separately (``*_dram_w``): weights bypass the
    GLB and stream through the double-buffered SRAM, so their latency hides
    behind PE-array compute (Section III-B) while their energy still counts.
    ``rd_dram``/``wr_dram`` hold the *activation/gradient* traffic whose
    latency is exposed.
    """

    rd_dram: float = 0.0
    wr_dram: float = 0.0
    rd_glb: float = 0.0
    wr_glb: float = 0.0
    rd_dram_w: float = 0.0  # weight reads (latency-hidden)
    wr_dram_w: float = 0.0  # weight/weight-gradient writes (latency-hidden)

    def __add__(self, o: "AccessCounts") -> "AccessCounts":
        return AccessCounts(
            self.rd_dram + o.rd_dram,
            self.wr_dram + o.wr_dram,
            self.rd_glb + o.rd_glb,
            self.wr_glb + o.wr_glb,
            self.rd_dram_w + o.rd_dram_w,
            self.wr_dram_w + o.wr_dram_w,
        )

    @property
    def dram_total(self) -> float:
        return self.rd_dram + self.wr_dram + self.rd_dram_w + self.wr_dram_w

    @property
    def dram_exposed(self) -> float:
        return self.rd_dram + self.wr_dram

    @property
    def dram_hidden(self) -> float:
        return self.rd_dram_w + self.wr_dram_w

    @property
    def glb_total(self) -> float:
        return self.rd_glb + self.wr_glb


def inference_layer_counts(
    workload: Workload, batch: int, mem: MemoryParams, d_w: int = 4
) -> list[AccessCounts]:
    """Algorithm 1, reported per layer (summing the list gives the totals).

    The per-layer breakdown is what ``repro.sim`` lowers into timed event
    streams; ``inference_access_counts`` keeps the aggregate API.
    """
    sizes = workload.entity_sizes_mb(batch, d_w)
    glb = mem.glb_mb
    per_layer: list[AccessCounts] = []
    n = len(sizes)
    for i, (I, O, W) in enumerate(sizes):
        acc = AccessCounts()
        per_layer.append(acc)
        first, last = i == 0, i == n - 1
        # --- GLB (lines 2, 4, 11) ---
        acc.rd_glb += I / mem.mbpa_glb
        if first:
            acc.wr_glb += (I + O) / mem.mbpa_glb
        else:
            acc.wr_glb += O / mem.mbpa_glb
        # --- DRAM reads (lines 3-9, 12-20) ---
        acc.rd_dram_w += W / mem.mbpa_dram  # weights always stream from DRAM
        if first:
            if I + W <= glb:
                acc.rd_dram += I / mem.mbpa_dram
            else:
                acc.rd_dram += I / mem.mbpa_dram + (I + W - glb) / mem.mbpa_dram
        else:
            prev_O = sizes[i - 1][1]
            if prev_O <= glb:
                # previous ofmap stayed on-chip; only weights stream in.
                pass
            else:
                if I + W <= glb:
                    acc.rd_dram += I / mem.mbpa_dram
                else:
                    acc.rd_dram += I / mem.mbpa_dram + (
                        I + W - glb
                    ) / mem.mbpa_dram
        # --- DRAM writes (lines 22-30) ---
        if last:
            acc.wr_dram += O / mem.mbpa_dram
        elif O > glb:
            acc.wr_dram += (O - glb) / mem.mbpa_dram
    return per_layer


def inference_access_counts(
    workload: Workload, batch: int, mem: MemoryParams, d_w: int = 4
) -> AccessCounts:
    """Algorithm 1."""
    return sum(inference_layer_counts(workload, batch, mem, d_w), AccessCounts())


def training_layer_counts(
    workload: Workload, batch: int, mem: MemoryParams, d_w: int = 4
) -> list[AccessCounts]:
    """Algorithm 2, reported per layer.  Gradient entities mirror forward
    entity sizes (GI=I, GO=O, GW=W), per the computational graph of Fig. 6."""
    sizes = workload.entity_sizes_mb(batch, d_w)
    glb = mem.glb_mb
    per_layer: list[AccessCounts] = []
    n = len(sizes)
    cum_layer = 0.0
    for i, (I, O, W) in enumerate(sizes):
        acc = AccessCounts()
        per_layer.append(acc)
        first, last = i == 0, i == n - 1
        GI, GO, GW = I, O, W
        layer_f = I + O + W
        layer_b = GI + GO + GW
        cum_layer += layer_f + layer_b
        # --- GLB counts (lines 9-10) ---
        acc.rd_glb += (3 * I + O + 5 * W) / mem.mbpa_glb
        acc.wr_glb += (2 * I + 2 * O + 3 * W) / mem.mbpa_glb
        acc.rd_dram_w += W / mem.mbpa_dram  # weights always stream from DRAM
        if cum_layer <= glb:
            # Whole cumulative working set resident: algorithmic minimum.
            if first:
                acc.rd_dram += I / mem.mbpa_dram
            if last:
                acc.wr_dram += O / mem.mbpa_dram
            # no backward-pass DRAM traffic (lines 19-20)
        else:
            # Forward pass behaves like inference (lines 22-30).
            if (not first) and sizes[i - 1][1] <= glb:
                pass  # only weights stream (already counted)
            else:
                if I + W <= glb:
                    acc.rd_dram += I / mem.mbpa_dram
                else:
                    acc.rd_dram += I / mem.mbpa_dram + (
                        I + W - glb
                    ) / mem.mbpa_dram
            if last:
                acc.wr_dram += O / mem.mbpa_dram
            # Backward pass (lines 31-37): spill gradients when oversized.
            # Gradient spills stream in a known order, so the double-buffered
            # SRAM prefetches most of them like weights; only a fraction of
            # the access latency is exposed (energy counts in full).
            if GI + GO + GW > glb:
                spill = (GI + GO + GW) / mem.mbpa_dram
                acc.wr_dram += spill * (1 - mem.prefetch_hidden_frac)
                acc.rd_dram += spill * (1 - mem.prefetch_hidden_frac)
                acc.wr_dram_w += spill * mem.prefetch_hidden_frac
                acc.rd_dram_w += spill * mem.prefetch_hidden_frac
        # Updated weights always write back (line 39).
        acc.wr_dram_w += W / mem.mbpa_dram
    return per_layer


def training_access_counts(
    workload: Workload, batch: int, mem: MemoryParams, d_w: int = 4
) -> AccessCounts:
    """Algorithm 2 aggregate totals."""
    return sum(training_layer_counts(workload, batch, mem, d_w), AccessCounts())


def per_layer_access_counts(
    workload: Workload,
    batch: int,
    mem: MemoryParams,
    mode: str = "inference",
    d_w: int = 4,
) -> list[AccessCounts]:
    if mode == "inference":
        return inference_layer_counts(workload, batch, mem, d_w)
    if mode == "training":
        return training_layer_counts(workload, batch, mem, d_w)
    raise ValueError(f"unknown mode {mode!r}")


def access_counts(
    workload: Workload,
    batch: int,
    mem: MemoryParams,
    mode: str = "inference",
    d_w: int = 4,
) -> AccessCounts:
    return sum(
        per_layer_access_counts(workload, batch, mem, mode, d_w), AccessCounts()
    )


def dram_reduction_pct(
    workload: Workload,
    batch: int,
    glb_mb: float,
    baseline_glb_mb: float,
    mode: str,
    d_w: int = 4,
) -> float:
    """Percent DRAM-access reduction vs a baseline GLB size (Figs. 9/11)."""
    base = access_counts(
        workload, batch, MemoryParams(glb_mb=baseline_glb_mb), mode, d_w
    ).dram_total
    cur = access_counts(workload, batch, MemoryParams(glb_mb=glb_mb), mode, d_w).dram_total
    if base == 0:
        return 0.0
    return 100.0 * (base - cur) / base
