"""Bandwidth expressions of Section III-A (Eqs. 1-8, Table II, softmax).

All ``*_per_cycle`` functions return **bytes/cycle**; multiply by the
accelerator frequency for bytes/sec (Eq. 1 with ``F_p = H_A*W_A*F_acc``).

Faithfulness notes
------------------
* Conv read BW is Eq. (7) exactly as printed:
    BW_RD = (k_h*k_w + if_h*if_w) * d_w / (k_h*k_w * of_h*of_w) * H_A*W_A
  (row-stationary dataflow; Eqs. 3-6 are its derivation).
* Conv write BW is Eq. (8): BW_WR = H_A*W_A*d_w / (k_h*k_w).
* FC/GEMM BW follows Table II's eight (M,N) x K cases exactly; table entries
  are elements/cycle and are scaled by ``d_w``.  The paper's published
  anchor — GPT-class write BW of 102 B/cycle for K=2048 on a 256x256 array
  at fp32 — reproduces exactly: W_A^2/(2*W_A+K-1)*4 = 102.4.
* Softmax SFU BW = d_w * H_A (Section III-A3).
"""

from __future__ import annotations

import dataclasses

from repro.core.workload import ConvLayer, GemmLayer, SoftmaxLayer, StreamingLayer, Layer


@dataclasses.dataclass(frozen=True)
class ArrayConfig:
    """Systolic PE array (paper Fig. 5)."""

    H_A: int = 256
    W_A: int = 256
    f_acc_hz: float = 1.0e9
    d_w: int = 4  # bytes per element (paper evaluates FP32)
    sfu_width: int | None = None  # defaults to H_A

    @property
    def peak_ops_per_sec(self) -> float:
        # Eq. (2): F_p = H_A * W_A * F_acc   (MACs/sec)
        return self.H_A * self.W_A * self.f_acc_hz


# ---------------------------------------------------------------------------
# Conv layer (Eqs. 3-8)
# ---------------------------------------------------------------------------


def conv_oi(layer: ConvLayer, d_w: int) -> float:
    """Eq. (6): operational intensity of a conv layer (MACs/byte)."""
    kk = layer.k_h * layer.k_w
    return (kk * layer.of_h * layer.of_w) / (
        d_w * (kk + layer.if_h * layer.if_w)
    )


def conv_read_bw_per_cycle(layer: ConvLayer, arr: ArrayConfig) -> float:
    """Eq. (7) in bytes/cycle."""
    kk = layer.k_h * layer.k_w
    return (
        (kk + layer.if_h * layer.if_w)
        * arr.d_w
        / (kk * layer.of_h * layer.of_w)
        * arr.H_A
        * arr.W_A
    )


def conv_write_bw_per_cycle(layer: ConvLayer, arr: ArrayConfig) -> float:
    """Eq. (8) in bytes/cycle."""
    return arr.H_A * arr.W_A * arr.d_w / (layer.k_h * layer.k_w)


# ---------------------------------------------------------------------------
# FC / GEMM layer (Table II, weight-stationary)
# ---------------------------------------------------------------------------


def gemm_read_bw_per_cycle(layer: GemmLayer, arr: ArrayConfig) -> float:
    """Table II read BW (elements/cycle * d_w), all eight cases."""
    M, N, K = layer.M, layer.N, layer.K
    H, W = arr.H_A, arr.W_A
    if M < H and N < W:
        if K < W:
            el = (M * N + K * M) / (N + K)
        else:
            el = (M * N + W * M) / (N + W)
    elif M < H and N >= W:
        if K < W:
            el = (M * W + K * M) / (N + K)
        else:
            el = (M * W + W * M) / (2 * W)
    elif M >= H and N < W:
        if K < W:
            el = (H * N + K * H) / (N + K)
        else:
            el = (H * N + W * H) / (W + N)
    else:  # M >= H and N >= W
        if K < W:
            el = (H * W + W * H) / (W + K)
        else:
            el = (H * W + W * H) / (2 * W)
    return el * arr.d_w


def gemm_write_bw_per_cycle(layer: GemmLayer, arr: ArrayConfig) -> float:
    """Table II write BW (elements/cycle * d_w)."""
    M, N, K = layer.M, layer.N, layer.K
    H, W = arr.H_A, arr.W_A
    if N < W:
        if K < W:
            el = (K * N) / (2 * N + K - 1)
        else:
            el = (W * N) / (2 * N + K - 1)
    else:
        if M < H:
            if K < W:
                el = (K * W) / (2 * W + K - 1)
            else:
                el = (W * W) / (2 * W + K - 1)
        else:
            if K < W:
                el = (W * N) / (2 * N + K - 1)
            else:
                el = (W * W) / (2 * W + K - 1)
    return el * arr.d_w


def softmax_bw_per_cycle(layer: SoftmaxLayer, arr: ArrayConfig) -> float:
    """Section III-A3: BW_softmax = d_w * H_A (SFU of width H_A)."""
    width = arr.sfu_width if arr.sfu_width is not None else arr.H_A
    return arr.d_w * width


def streaming_bw_per_cycle(layer: StreamingLayer, arr: ArrayConfig) -> float:
    """TPU adaptation: streaming ops demand peak vector-unit bandwidth.

    An attention-free streaming op (SSD scan / norm) keeps one vector lane
    row busy per cycle: BW = d_w * H_A, same form as the SFU softmax.
    """
    return arr.d_w * arr.H_A


# ---------------------------------------------------------------------------
# Workload-level rollups
# ---------------------------------------------------------------------------


def layer_read_bw_per_cycle(layer: Layer, arr: ArrayConfig) -> float:
    if isinstance(layer, ConvLayer):
        return conv_read_bw_per_cycle(layer, arr)
    if isinstance(layer, GemmLayer):
        return gemm_read_bw_per_cycle(layer, arr)
    if isinstance(layer, SoftmaxLayer):
        return softmax_bw_per_cycle(layer, arr)
    return streaming_bw_per_cycle(layer, arr)


def layer_write_bw_per_cycle(layer: Layer, arr: ArrayConfig) -> float:
    if isinstance(layer, ConvLayer):
        return conv_write_bw_per_cycle(layer, arr)
    if isinstance(layer, GemmLayer):
        return gemm_write_bw_per_cycle(layer, arr)
    if isinstance(layer, SoftmaxLayer):
        return softmax_bw_per_cycle(layer, arr)
    return streaming_bw_per_cycle(layer, arr)


def workload_peak_bw(workload, arr: ArrayConfig) -> dict[str, float]:
    """Peak read/write bytes-per-cycle demand over all layers (Fig. 7/8)."""
    rd = max(layer_read_bw_per_cycle(l, arr) for l in workload.layers)
    wr = max(layer_write_bw_per_cycle(l, arr) for l in workload.layers)
    return {"read_bytes_per_cycle": rd, "write_bytes_per_cycle": wr}


def required_bw_bytes_per_sec(oi: float, arr: ArrayConfig) -> float:
    """Eq. (1): BW = F_p / OI."""
    return arr.peak_ops_per_sec / oi
