"""Closed-loop STCO <-> DTCO (paper Fig. 1).

Pipeline:
  1. Profile the workload: peak read/write BW demand (Section III-A) and
     DRAM-access-vs-GLB-size curve (Algorithms 1/2).
  2. Pick the GLB capacity at the knee of the DRAM-reduction curve (the
     paper lands on 64 MB for inference, 256 MB for training).
  3. Run DTCO to find the SOT-MRAM bitcell meeting that bandwidth at
     min energy*area with retention >= cache data lifetime.
  4. Evaluate the full system and emit the Pareto set over
     (energy, latency, area) across candidate capacities/technologies.

Steps 1 and 4 run through the batched ``repro.dse`` evaluator: one array
program covers the whole capacity x technology grid instead of a Python
loop per point (``engine="scalar"`` keeps the original loop as the
bit-compatibility reference — see ``tests/test_dse_equivalence.py``).
"""

from __future__ import annotations

import dataclasses

from repro.core import dtco
from repro.core.access_counts import MemoryParams, access_counts
from repro.core.bandwidth import ArrayConfig, workload_peak_bw
from repro.core.evaluate import SystemMetrics, evaluate_system
from repro.core.memory_system import HybridMemorySystem, glb_array, sot_array_from_device
from repro.core.workload import Workload


def _capacity_grid() -> tuple[float, ...]:
    from repro.spec import DEFAULT_CAPACITY_GRID_MB

    return DEFAULT_CAPACITY_GRID_MB


def _technology_grid() -> tuple[str, ...]:
    from repro.spec import tech_group

    return tech_group("paper")


def __getattr__(name):
    # Registry-derived grid defaults (see repro.spec); the names stay the
    # long-standing import surface of this module (``CAPACITY_GRID_MB``,
    # ``TECHNOLOGY_GRID``).  Resolved lazily (PEP 562, cached in globals)
    # because repro.spec itself imports repro.core.memory_system — an eager
    # import here would make the package import order matter.
    if name in ("CAPACITY_GRID_MB", "TECHNOLOGY_GRID"):
        g = globals()
        g["CAPACITY_GRID_MB"] = _capacity_grid()
        g["TECHNOLOGY_GRID"] = _technology_grid()
        return g[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass(frozen=True)
class STCOPoint:
    technology: str
    capacity_mb: float
    metrics: SystemMetrics
    area_mm2: float


@dataclasses.dataclass(frozen=True)
class STCOResult:
    workload: str
    mode: str
    peak_read_bw_bytes_per_cycle: float
    peak_write_bw_bytes_per_cycle: float
    chosen_capacity_mb: float
    dtco: dtco.DTCOResult
    pareto: tuple[STCOPoint, ...]
    all_points: tuple[STCOPoint, ...]


def dram_access_curve(
    workload: Workload, batch: int, mode: str, d_w: int = 4,
    engine: str = "vectorized",
) -> dict[float, float]:
    """Total DRAM accesses vs GLB capacity (the Fig. 9/11 reduction curve)."""
    if engine == "vectorized":
        from repro.dse import GridSpec, evaluate_workload_grid
        from repro.spec import BASELINE_TECH

        # Access counts are technology-independent; one tech suffices.
        spec = GridSpec(
            capacities_mb=_capacity_grid(), technologies=(BASELINE_TECH,),
            batches=(batch,), modes=(mode,), d_w=d_w,
        )
        g = evaluate_workload_grid(workload, spec, backend="numpy")
        return g.dram_curve(mode, batch)
    return {
        cap: access_counts(
            workload, batch, MemoryParams(glb_mb=cap), mode, d_w
        ).dram_total
        for cap in _capacity_grid()
    }


def knee_capacity(
    curve: dict[float, float], threshold: float = 0.05, strategy: str = "cliff"
) -> float:
    """Pick the GLB capacity at the knee of a DRAM-access curve.

    ``strategy="cliff"`` (default): the capacity that completes the largest
    relative per-doubling reduction — robust on the non-convex curves the
    model zoos produce, and it reproduces the paper's operating points
    (64 MB CV inference, 256 MB NLP training; see tests/test_golden.py).
    ``threshold`` is the minimum relative reduction that counts as a cliff:
    if no doubling gains that much the curve is flat and the smallest
    capacity wins.  On curves still dropping steeply at the end of the grid
    (e.g. gpt3-class working sets) the biggest cliff can be the last
    doubling, so the pick saturates at the grid maximum — extend the grid
    if that happens.

    ``strategy="threshold"``: the original rule — smallest capacity whose
    next doubling buys < ``threshold`` relative reduction.  It knees
    prematurely on curves with a flat head (e.g. training curves dominated
    by capacity-independent weight traffic at small capacities).
    """
    caps = sorted(curve)
    if strategy == "threshold":
        for a, b in zip(caps, caps[1:]):
            if curve[a] <= 0:
                return a
            if (curve[a] - curve[b]) / curve[a] < threshold:
                return a
        return caps[-1]
    if strategy != "cliff":
        raise ValueError(f"unknown knee strategy {strategy!r}")
    best_gain, knee = 0.0, caps[0]
    for a, b in zip(caps, caps[1:]):
        if curve[a] <= 0:
            continue
        gain = (curve[a] - curve[b]) / curve[a]
        if gain >= threshold and gain > best_gain:
            best_gain, knee = gain, b
    return knee


def pareto_front(points: list[STCOPoint]) -> list[STCOPoint]:
    """Non-dominated subset over (energy, latency, area), in input order.

    Delegates to the O(n log n) staircase sweep in ``repro.dse.pareto``
    (the previous implementation was the all-pairs O(n^2) check, kept as
    ``repro.dse.pareto.pareto_indices_naive`` for equivalence testing).
    """
    import numpy as np

    from repro.dse.pareto import pareto_indices

    if not points:
        return []
    objs = np.asarray(
        [(p.metrics.energy_j, p.metrics.latency_s, p.area_mm2) for p in points]
    )
    return [points[i] for i in pareto_indices(objs)]


def grid_points_scalar(
    workload: Workload, batch: int, mode: str, d_w: int = 4
) -> list[STCOPoint]:
    """The original per-point Python loop over technology x capacity.

    Public on purpose: it is the bit-compatibility reference the
    equivalence tests and the ``benchmarks/explore`` speedup harness
    measure the vectorized engine against.
    """
    points: list[STCOPoint] = []
    for tech in _technology_grid():
        for c in _capacity_grid():
            g = glb_array(tech, c)
            m = evaluate_system(
                workload, batch, HybridMemorySystem(glb=g), mode, d_w
            )
            points.append(STCOPoint(tech, c, m, g.area_mm2))
    return points


def run_stco(
    workload: Workload,
    batch: int = 16,
    mode: str = "inference",
    arr: ArrayConfig | None = None,
    d_w: int = 4,
    engine: str = "vectorized",
    backend: str = "numpy",
) -> STCOResult:
    arr = arr or ArrayConfig()
    bw = workload_peak_bw(workload, arr)

    # One batched evaluation supplies both the DRAM curve (counts are
    # technology-independent) and every technology x capacity design point.
    if engine == "vectorized":
        from repro.dse import GridSpec, evaluate_workload_grid

        caps, techs = _capacity_grid(), _technology_grid()
        spec = GridSpec(
            capacities_mb=caps, technologies=techs,
            batches=(batch,), modes=(mode,), d_w=d_w,
        )
        g = evaluate_workload_grid(workload, spec, backend=backend)
        curve = g.dram_curve(mode, batch)
        points = [
            STCOPoint(tech, c, g.point(mode, tech, batch, c), g.area_mm2(tech, c))
            for tech in techs
            for c in caps
        ]
    elif engine == "scalar":
        curve = dram_access_curve(workload, batch, mode, d_w, engine="scalar")
        points = grid_points_scalar(workload, batch, mode, d_w)
    else:
        raise ValueError(f"unknown engine {engine!r}")
    cap = knee_capacity(curve)

    target = dtco.DTCOTarget(
        read_bw_bytes_per_cycle=bw["read_bytes_per_cycle"],
        write_bw_bytes_per_cycle=bw["write_bytes_per_cycle"],
        f_acc_hz=arr.f_acc_hz,
    )
    dt = dtco.optimize(target)
    # The DTCO-derived device as its own design point at the chosen capacity.
    g = sot_array_from_device(cap, dt.device)
    m = evaluate_system(workload, batch, HybridMemorySystem(glb=g), mode, d_w)
    points.append(STCOPoint("sot_dtco_device", cap, m, g.area_mm2))

    return STCOResult(
        workload=workload.name,
        mode=mode,
        peak_read_bw_bytes_per_cycle=bw["read_bytes_per_cycle"],
        peak_write_bw_bytes_per_cycle=bw["write_bytes_per_cycle"],
        chosen_capacity_mb=cap,
        dtco=dt,
        pareto=tuple(pareto_front(points)),
        all_points=tuple(points),
    )
