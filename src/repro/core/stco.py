"""Closed-loop STCO <-> DTCO (paper Fig. 1).

Pipeline:
  1. Profile the workload: peak read/write BW demand (Section III-A) and
     DRAM-access-vs-GLB-size curve (Algorithms 1/2).
  2. Pick the GLB capacity at the knee of the DRAM-reduction curve (the
     paper lands on 64 MB for inference, 256 MB for training).
  3. Run DTCO to find the SOT-MRAM bitcell meeting that bandwidth at
     min energy*area with retention >= cache data lifetime.
  4. Evaluate the full system and emit the Pareto set over
     (energy, latency, area) across candidate capacities/technologies.
"""

from __future__ import annotations

import dataclasses

from repro.core import dtco
from repro.core.access_counts import MemoryParams, access_counts
from repro.core.bandwidth import ArrayConfig, workload_peak_bw
from repro.core.evaluate import SystemMetrics, evaluate_system
from repro.core.memory_system import HybridMemorySystem, glb_array, sot_array_from_device
from repro.core.workload import Workload

CAPACITY_GRID_MB: tuple[float, ...] = (2, 4, 8, 16, 32, 64, 128, 256, 512)


@dataclasses.dataclass(frozen=True)
class STCOPoint:
    technology: str
    capacity_mb: float
    metrics: SystemMetrics
    area_mm2: float


@dataclasses.dataclass(frozen=True)
class STCOResult:
    workload: str
    mode: str
    peak_read_bw_bytes_per_cycle: float
    peak_write_bw_bytes_per_cycle: float
    chosen_capacity_mb: float
    dtco: dtco.DTCOResult
    pareto: tuple[STCOPoint, ...]
    all_points: tuple[STCOPoint, ...]


def dram_access_curve(
    workload: Workload, batch: int, mode: str, d_w: int = 4
) -> dict[float, float]:
    return {
        cap: access_counts(
            workload, batch, MemoryParams(glb_mb=cap), mode, d_w
        ).dram_total
        for cap in CAPACITY_GRID_MB
    }


def knee_capacity(curve: dict[float, float], threshold: float = 0.05) -> float:
    """Smallest capacity whose next doubling buys < ``threshold`` reduction."""
    caps = sorted(curve)
    for a, b in zip(caps, caps[1:]):
        if curve[a] <= 0:
            return a
        if (curve[a] - curve[b]) / curve[a] < threshold:
            return a
    return caps[-1]


def pareto_front(points: list[STCOPoint]) -> list[STCOPoint]:
    front = []
    for p in points:
        dominated = any(
            q.metrics.energy_j <= p.metrics.energy_j
            and q.metrics.latency_s <= p.metrics.latency_s
            and q.area_mm2 <= p.area_mm2
            and (
                q.metrics.energy_j < p.metrics.energy_j
                or q.metrics.latency_s < p.metrics.latency_s
                or q.area_mm2 < p.area_mm2
            )
            for q in points
        )
        if not dominated:
            front.append(p)
    return front


def run_stco(
    workload: Workload,
    batch: int = 16,
    mode: str = "inference",
    arr: ArrayConfig | None = None,
    d_w: int = 4,
) -> STCOResult:
    arr = arr or ArrayConfig()
    bw = workload_peak_bw(workload, arr)

    curve = dram_access_curve(workload, batch, mode, d_w)
    cap = knee_capacity(curve)

    target = dtco.DTCOTarget(
        read_bw_bytes_per_cycle=bw["read_bytes_per_cycle"],
        write_bw_bytes_per_cycle=bw["write_bytes_per_cycle"],
        f_acc_hz=arr.f_acc_hz,
    )
    dt = dtco.optimize(target)

    points: list[STCOPoint] = []
    for tech in ("sram", "sot", "sot_opt"):
        for c in CAPACITY_GRID_MB:
            g = glb_array(tech, c)
            m = evaluate_system(
                workload, batch, HybridMemorySystem(glb=g), mode, d_w
            )
            points.append(STCOPoint(tech, c, m, g.area_mm2))
    # The DTCO-derived device as its own design point at the chosen capacity.
    g = sot_array_from_device(cap, dt.device)
    m = evaluate_system(workload, batch, HybridMemorySystem(glb=g), mode, d_w)
    points.append(STCOPoint("sot_dtco_device", cap, m, g.area_mm2))

    return STCOResult(
        workload=workload.name,
        mode=mode,
        peak_read_bw_bytes_per_cycle=bw["read_bytes_per_cycle"],
        peak_write_bw_bytes_per_cycle=bw["write_bytes_per_cycle"],
        chosen_capacity_mb=cap,
        dtco=dt,
        pareto=tuple(pareto_front(points)),
        all_points=tuple(points),
    )
