"""DTCO of SOT-MRAM (paper Section IV) — device/circuit physics models.

Implements, with SI units throughout:
  * Eq. (9): critical switching current density ``j_c`` and cell current
    ``I_c = j_c * w_SOT * t_SOT`` as functions of the spin-Hall angle
    theta_SH, free-layer thickness ``t_FL``, SOT-layer geometry, effective
    anisotropy field and applied field.
  * SOT-layer thickness bulk effect: effective spin-Hall efficiency
    ``theta_eff(t) = theta_SH * (1 - sech(t/lambda_sf))`` -> the I_c-vs-t_SOT
    optimum near 3 nm of Fig. 13(c).
  * Eq. (10): write pulse width ``tau_p ~ 1/(j_sw - j_c)`` (faster switching
    at higher overdrive; 180-520 ps anchors from [31][32][33] + Table VI).
  * Thermal stability factor Delta = E_b/(k_B T) with E_b = mu0*Ms*H_k*V/2,
    retention time t_ret = tau_th * exp(Delta) * P_RF for a target
    retention-failure rate (Fig. 14(b): Delta=70 -> >10 years; Delta=45 ->
    seconds-range cache lifetime).
  * TMR vs MgO thickness (Fig. 15(a), calibrated to Table VI: 3 nm -> 240%)
    and read latency vs TMR (Fig. 15(b); sensing margin ~ TMR/(2+TMR)).
  * Process/temperature Monte-Carlo (Section V-D1): Gaussian d_MTJ, t_FL,
    w_SOT with sigma = 5% mu, clipped at 4 sigma; +30% guard-band.
  * ``optimize()``: the closed-loop DTCO search that reproduces the paper's
    Table VI operating point given workload bandwidth demands.

Physical constants are exact SI; material parameters default to CoFeB/MgO on
a topological-insulator or heavy-metal channel, calibrated so the published
anchor points reproduce (see tests/test_dtco.py).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

# --- physical constants (SI) ---
E_CHARGE = 1.602176634e-19  # C
HBAR = 1.054571817e-34  # J s
MU0 = 4e-7 * math.pi  # H/m
KB = 1.380649e-23  # J/K


@dataclasses.dataclass(frozen=True)
class SOTDevice:
    """A candidate SOT-MRAM bitcell design point."""

    theta_sh: float = 1.0  # spin-Hall angle (Table VI optimum: 1)
    t_fl_nm: float = 0.5  # free-layer thickness (Table VI: 0.5 nm)
    w_sot_nm: float = 130.0  # SOT-layer width (Table VI: 130 nm)
    t_sot_nm: float = 3.0  # SOT-layer thickness (Table VI: 3 nm)
    t_mgo_nm: float = 3.0  # MgO barrier (Table VI: 3 nm -> TMR 240%)
    d_mtj_nm: float = 55.0  # MTJ diameter (Table VI: 55 nm)
    # material parameters
    ms_a_per_m: float = 1.0e6  # free-layer saturation magnetisation
    # Calibrated so the Table VI cell (d=55nm, t_FL=0.5nm) has Delta = 45.
    hk_eff_a_per_m: float = 2.5e5  # effective anisotropy field
    hx_a_per_m: float = 0.0  # applied in-plane field (field-free switching)
    lambda_sf_nm: float = 1.8  # spin-diffusion length in the channel
    temp_k: float = 300.0


# ---------------------------------------------------------------------------
# Eq. (9): critical switching current
# ---------------------------------------------------------------------------


def theta_eff(dev: SOTDevice) -> float:
    """Bulk spin-Hall effect: thin channels lose efficiency (Fig. 13(c))."""
    x = dev.t_sot_nm / dev.lambda_sf_nm
    return dev.theta_sh * (1.0 - 1.0 / math.cosh(x))


def critical_current_density(dev: SOTDevice) -> float:
    """Eq. (9), A/m^2."""
    t_fl = dev.t_fl_nm * 1e-9
    field_term = dev.hk_eff_a_per_m / 2.0 - dev.hx_a_per_m / math.sqrt(2.0)
    return (
        2.0
        * E_CHARGE
        * MU0
        * dev.ms_a_per_m
        * t_fl
        / (HBAR * theta_eff(dev))
        * field_term
    )


def critical_current(dev: SOTDevice) -> float:
    """I_c in amperes: j_c times the SOT-channel cross-section."""
    area = (dev.w_sot_nm * 1e-9) * (dev.t_sot_nm * 1e-9)
    return critical_current_density(dev) * area


# ---------------------------------------------------------------------------
# Eq. (10): write pulse width
# ---------------------------------------------------------------------------

# Calibrated so the Table VI device at ~2x overdrive writes in 520 ps and
# high-overdrive demonstrations reach ~180-210 ps [31][33].
_TAU_COEFF_S = 0.52e-9  # pulse width at j_sw = 2*j_c for the optimum cell


def write_pulse_width_s(dev: SOTDevice, overdrive: float = 2.0) -> float:
    """tau_p ~ 1/(j_sw - j_c); expressed via the overdrive ratio j_sw/j_c."""
    if overdrive <= 1.0:
        return math.inf
    return _TAU_COEFF_S / (overdrive - 1.0)


def write_pulse_width_vs_current(dev: SOTDevice, i_sw_a: float) -> float:
    """tau_p as a function of the applied switching current (Fig. 14(a))."""
    i_c = critical_current(dev)
    if i_sw_a <= i_c:
        return math.inf
    return _TAU_COEFF_S * i_c / (i_sw_a - i_c)


# ---------------------------------------------------------------------------
# Thermal stability, retention (Fig. 14(b))
# ---------------------------------------------------------------------------

_TAU_THERMAL_S = 1e-9  # attempt time


def thermal_stability(dev: SOTDevice) -> float:
    """Delta = E_b / (k_B T), E_b = mu0 * Ms * H_k * V / 2."""
    r = dev.d_mtj_nm * 1e-9 / 2.0
    volume = math.pi * r * r * (dev.t_fl_nm * 1e-9)
    e_b = MU0 * dev.ms_a_per_m * dev.hk_eff_a_per_m * volume / 2.0
    return e_b / (KB * dev.temp_k)


def retention_time_s(dev: SOTDevice, p_rf: float = 1e-9) -> float:
    """Retention for a target failure rate: t = tau * P_RF * exp(Delta)."""
    delta = thermal_stability(dev)
    # Guard against overflow for very stable cells.
    if delta > 700:
        return math.inf
    return _TAU_THERMAL_S * p_rf * math.exp(delta)


# ---------------------------------------------------------------------------
# TMR & read latency (Fig. 15)
# ---------------------------------------------------------------------------


def tmr_percent(t_mgo_nm: float) -> float:
    """TMR grows with barrier thickness, saturating (Tsunekawa [29]).

    Calibrated: 1 nm -> ~95%, 3 nm -> 240% (Table VI), saturate ~300%.
    """
    return 300.0 * (1.0 - math.exp(-t_mgo_nm / 1.83))


def read_latency_s(tmr_pct: float) -> float:
    """Sense latency ~ 1/sensing-margin; SM ~ TMR/(2+TMR) (Fig. 15(b)).

    Calibrated so TMR=240% reads in 250 ps (Section V-D3):
    t_read = 250ps * SM(240%) / SM(tmr).
    """
    tmr = tmr_pct / 100.0
    sm = tmr / (2.0 + tmr)
    sm_ref = 2.4 / 4.4
    return 0.25e-9 * sm_ref / sm


def read_pulse_width_s(dev: SOTDevice) -> float:
    return read_latency_s(tmr_percent(dev.t_mgo_nm))


# ---------------------------------------------------------------------------
# Bitcell energies (Table VII anchors)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BitcellPPA:
    read_latency_s: float
    write_latency_s: float
    read_energy_j: float
    write_energy_j: float
    # per-bit leakage power (W); near-zero for MRAM
    leakage_w_per_bit: float
    area_um2_per_bit: float


def bitcell_ppa(dev: SOTDevice, vdd: float = 0.8, overdrive: float = 2.0) -> BitcellPPA:
    """Dynamic energy = I * V * t for read and write paths.

    With the Table VI cell this lands on the Table VII numbers: read current
    ~20/33 uA for 250 ps; write current = overdrive * I_c for tau_p.
    """
    t_rd = read_pulse_width_s(dev)
    t_wr = write_pulse_width_s(dev, overdrive)
    i_rd = 26.5e-6  # mean of I_data0=20uA / I_data1=33uA (Section V-D3)
    i_wr = max(overdrive * critical_current(dev), 50e-6)
    # Periphery (sense amp + current mirror) adds a fixed energy floor.
    e_rd = i_rd * vdd * t_rd + 15e-15
    e_wr = i_wr * vdd * t_wr + 10e-15
    # Area: 2T1SOT cell; MTJ pitch-limited. ~0.028 um^2/bit at 14 nm,
    # shrinking with d_MTJ (SRAM 14nm 6T reference: ~0.081 um^2/bit * 2x
    # periphery discussed in memory_system.py).
    area = 0.020 + 0.008 * (dev.d_mtj_nm / 55.0) ** 2
    return BitcellPPA(
        read_latency_s=t_rd,
        write_latency_s=t_wr,
        read_energy_j=e_rd,
        write_energy_j=e_wr,
        leakage_w_per_bit=1e-16,  # near-zero NVM leakage
        area_um2_per_bit=area,
    )


# ---------------------------------------------------------------------------
# Process & temperature variation (Section V-D1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VariationResult:
    worst_write_ic_a: float  # at +4 sigma, T_cold
    worst_read_retention_s: float  # at -4 sigma, T_hot
    worst_read_delta: float
    yield_fraction: float


def monte_carlo_variation(
    dev: SOTDevice,
    n_samples: int = 5000,
    sigma_frac: float = 0.05,
    t_hot_k: float = 358.0,
    seed: int = 0,
    retention_req_s: float = 1.0,
) -> VariationResult:
    """Gaussian d_MTJ/t_FL/w_SOT, 4-sigma clipped Monte-Carlo."""
    rng = np.random.default_rng(seed)

    def sample(mu: float) -> np.ndarray:
        s = rng.normal(mu, sigma_frac * mu, n_samples)
        return np.clip(s, mu * (1 - 4 * sigma_frac), mu * (1 + 4 * sigma_frac))

    d_mtj = sample(dev.d_mtj_nm)
    t_fl = sample(dev.t_fl_nm)
    w_sot = sample(dev.w_sot_nm)

    # Worst-case write: +4 sigma geometry (largest I_c), T_cold (Eq. 9/10
    # are T-independent, so geometry dominates).
    hi = dataclasses.replace(
        dev,
        d_mtj_nm=dev.d_mtj_nm * (1 + 4 * sigma_frac),
        t_fl_nm=dev.t_fl_nm * (1 + 4 * sigma_frac),
        w_sot_nm=dev.w_sot_nm * (1 + 4 * sigma_frac),
    )
    worst_ic = critical_current(hi)

    # Worst-case read/retention: -4 sigma, T_hot (Delta shrinks with T).
    lo = dataclasses.replace(
        dev,
        d_mtj_nm=dev.d_mtj_nm * (1 - 4 * sigma_frac),
        t_fl_nm=dev.t_fl_nm * (1 - 4 * sigma_frac),
        w_sot_nm=dev.w_sot_nm * (1 - 4 * sigma_frac),
        temp_k=t_hot_k,
    )
    worst_delta = thermal_stability(lo)
    worst_ret = retention_time_s(lo)

    # Yield: fraction of sampled cells meeting the retention requirement at
    # T_hot.
    r = d_mtj * 1e-9 / 2.0
    vol = math.pi * r * r * (t_fl * 1e-9)
    delta = MU0 * dev.ms_a_per_m * dev.hk_eff_a_per_m * vol / 2.0 / (KB * t_hot_k)
    ret = _TAU_THERMAL_S * 1e-9 * np.exp(np.minimum(delta, 700.0))
    yield_frac = float(np.mean(ret >= retention_req_s))
    return VariationResult(
        worst_write_ic_a=worst_ic,
        worst_read_retention_s=worst_ret,
        worst_read_delta=worst_delta,
        yield_fraction=yield_frac,
    )


def apply_guard_band(dev: SOTDevice, frac: float = 0.30) -> SOTDevice:
    """Add the paper's 30% PT guard-band to thickness/width parameters."""
    return dataclasses.replace(
        dev,
        t_fl_nm=dev.t_fl_nm * (1 + frac),
        w_sot_nm=dev.w_sot_nm * (1 + frac),
        t_sot_nm=dev.t_sot_nm,
    )


# ---------------------------------------------------------------------------
# Closed-loop DTCO optimizer (Fig. 1 right loop)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DTCOTarget:
    read_bw_bytes_per_cycle: float  # from STCO workload profiling
    write_bw_bytes_per_cycle: float
    f_acc_hz: float = 1.0e9
    data_lifetime_s: float = 10.0  # cache-resident data lifetime
    p_rf: float = 1e-9


@dataclasses.dataclass(frozen=True)
class DTCOResult:
    device: SOTDevice
    ppa: BitcellPPA
    bits_per_bank_cycle_read: float
    bits_per_bank_cycle_write: float
    read_bus_bits: int
    write_bus_bits: int
    retention_s: float
    delta: float


def optimize(
    target: DTCOTarget,
    theta_candidates: tuple[float, ...] = (0.1, 0.3, 0.5, 1.0, 2.0, 10.0, 152.0),
    t_fl_grid_nm: tuple[float, ...] = (0.5, 0.8, 1.0, 1.2),
    w_sot_grid_nm: tuple[float, ...] = (80.0, 100.0, 130.0, 160.0, 200.0),
    t_mgo_grid_nm: tuple[float, ...] = (1.5, 2.0, 2.5, 3.0),
    d_mtj_grid_nm: tuple[float, ...] = (35.0, 45.0, 55.0, 70.0, 88.0),
) -> DTCOResult:
    """Grid-search the DTCO space for min energy*area subject to:
      * retention >= data lifetime at the target failure rate,
      * worst-case (guard-banded) cell still switches within a cycle budget.
    The returned bus widths satisfy the workload bandwidth demand by
    widening the memory bus (Section V-D3 'dynamically allocate the memory
    bus width on-demand')."""
    best: tuple[float, DTCOResult] | None = None
    cycle_s = 1.0 / target.f_acc_hz
    for th in theta_candidates:
        for t_fl in t_fl_grid_nm:
            for w in w_sot_grid_nm:
                for t_mgo in t_mgo_grid_nm:
                    for d in d_mtj_grid_nm:
                        dev = SOTDevice(
                            theta_sh=th,
                            t_fl_nm=t_fl,
                            w_sot_nm=w,
                            t_mgo_nm=t_mgo,
                            d_mtj_nm=d,
                        )
                        ret = retention_time_s(dev, target.p_rf)
                        if ret < target.data_lifetime_s:
                            continue
                        gb = apply_guard_band(dev)
                        ppa = bitcell_ppa(gb)
                        if ppa.write_latency_s > 4 * cycle_s:
                            continue  # unusably slow write
                        # bits transferable per accelerator cycle per bank
                        rd_rate = cycle_s / ppa.read_latency_s
                        wr_rate = cycle_s / ppa.write_latency_s
                        rd_bus = math.ceil(
                            target.read_bw_bytes_per_cycle * 8 / max(rd_rate, 1e-9)
                        )
                        wr_bus = math.ceil(
                            target.write_bw_bytes_per_cycle * 8 / max(wr_rate, 1e-9)
                        )
                        cost = (
                            (ppa.read_energy_j + ppa.write_energy_j)
                            * ppa.area_um2_per_bit
                            * (1.0 + 0.1 * (rd_bus + wr_bus) / 4096)
                        )
                        res = DTCOResult(
                            device=dev,
                            ppa=ppa,
                            bits_per_bank_cycle_read=rd_rate,
                            bits_per_bank_cycle_write=wr_rate,
                            read_bus_bits=rd_bus,
                            write_bus_bits=wr_bus,
                            retention_s=ret,
                            delta=thermal_stability(dev),
                        )
                        if best is None or cost < best[0]:
                            best = (cost, res)
    assert best is not None, "DTCO search found no feasible device"
    return best[1]
