"""Array-level PPA models and the hybrid memory system (paper Section V-E).

The paper extracts array-level latency/energy/area from a modified Destiny
simulator fed with Cadence-characterised bitcell data (Synopsys 14 nm PDK).
None of those tools exist here, so this module provides a *calibrated
analytical* array model with the paper's own published numbers as anchors:

  * Table VII bitcell dynamic power (uW): SRAM 426 rd / 373 wr;
    SOT-MRAM 150/368 rd, 325/300 wr.
  * DTCO-opt SOT access: 250 ps read / 520 ps write (Section V-D3).
  * "At smaller capacity, SRAM is way faster than SOT-MRAM" [10][14];
    at large capacity the density advantage reverses the ordering.
  * Area at iso-capacity: SOT-opt = 0.54x SRAM @64 MB, 0.52x @256 MB
    (Fig. 19).
  * System-level results (Fig. 18): SOT @64 MB inference ~5x energy / ~2x
    latency better than SRAM; DTCO-opt ~7x / ~8x; training @256 MB:
    6x/2x and 8x/9x.

Scaling laws: dynamic access energy and latency grow ~sqrt(capacity)
(wordline/bitline + H-tree RC), leakage and area grow linearly.  SOT-MRAM's
~2x density halves the wire lengths at iso-capacity, which is why its
latency/energy curves cross SRAM's as capacity grows.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.dtco import SOTDevice, bitcell_ppa, read_pulse_width_s, write_pulse_width_s

MB = 1024 * 1024


@dataclasses.dataclass(frozen=True)
class ArrayPPA:
    """PPA of one GLB built from a given technology at a given capacity."""

    technology: str
    capacity_mb: float
    read_latency_ns: float
    write_latency_ns: float
    read_energy_pj_per_access: float  # per 256B GLB access
    write_energy_pj_per_access: float
    leakage_w: float
    area_mm2: float
    banks: int
    # Which registered MemTechSpec produced this PPA (defaults to
    # ``technology``); bespoke builds (e.g. the DTCO-device point) carry a
    # non-registered name so spec-identity checks know to skip them.
    spec_name: str = ""

    def __post_init__(self):
        if not self.spec_name:
            object.__setattr__(self, "spec_name", self.technology)


# --- 14 nm technology constants (calibration documented above) -------------

# SRAM: 6T bitcell 0.081 um^2 -> with periphery ~0.160 um^2/bit.
_SRAM_AREA_UM2_PER_BIT = 0.160
# SOT: 2T1SOT, denser; DTCO-opt shrinks MTJ+SOT footprint further.
_SOT_AREA_UM2_PER_BIT = 0.096  # ~0.60x SRAM
_SOT_OPT_AREA_UM2_PER_BIT = 0.084  # ~0.53x SRAM (Fig. 19: 0.54x/0.52x)

# Leakage: 14 nm SRAM ~ 25 mW/MB (dominant at 64-256 MB); MRAM array leakage
# is periphery-only (~2% of SRAM's).
_SRAM_LEAK_W_PER_MB = 0.030
_SOT_LEAK_W_PER_MB = 0.0005

# Dynamic energy per 256-byte access at a 2 MB reference array, from the
# Table VII bitcell powers integrated over the access time.
_SRAM_E_RD_PJ_2MB = 150.0
_SRAM_E_WR_PJ_2MB = 131.0
_SOT_E_RD_PJ_2MB = 58.0  # (150+368)/2 uW vs 426 uW ratio applied
_SOT_E_WR_PJ_2MB = 70.0  # (325+300)/2 vs 373
_SOT_OPT_E_RD_PJ_2MB = 34.0  # DTCO: higher TMR -> lighter sensing
_SOT_OPT_E_WR_PJ_2MB = 42.0  # DTCO: lower I_c -> cheaper switching

# Latency at the 2 MB reference and sqrt-capacity growth coefficients.
# SRAM is fastest when small; SOT cell access is slower but its array wiring
# grows ~sqrt(area) with a ~2x density advantage, so it scales flatter.
_SRAM_T0_NS, _SRAM_TG_NS = 0.45, 0.42
_SOT_T0_RD_NS, _SOT_TG_RD_NS = 1.05, 0.145
_SOT_T0_WR_NS, _SOT_TG_WR_NS = 1.60, 0.155
_SOT_OPT_T0_RD_NS, _SOT_OPT_TG_RD_NS = 0.38, 0.052
_SOT_OPT_T0_WR_NS, _SOT_OPT_TG_WR_NS = 0.68, 0.060


def _sqrt_scale(cap_mb: float) -> float:
    return math.sqrt(cap_mb / 2.0)


def sram_array(capacity_mb: float) -> ArrayPPA:
    s = _sqrt_scale(capacity_mb)
    # 4 MB SRAM macro banks (typical 14nm compiler granularity).
    banks = max(1, int(capacity_mb // 4))
    return ArrayPPA(
        technology="sram",
        capacity_mb=capacity_mb,
        read_latency_ns=_SRAM_T0_NS + _SRAM_TG_NS * s,
        write_latency_ns=_SRAM_T0_NS + _SRAM_TG_NS * s,
        read_energy_pj_per_access=_SRAM_E_RD_PJ_2MB * (1 + 0.70 * (s - 1)),
        write_energy_pj_per_access=_SRAM_E_WR_PJ_2MB * (1 + 0.70 * (s - 1)),
        leakage_w=_SRAM_LEAK_W_PER_MB * capacity_mb,
        area_mm2=_SRAM_AREA_UM2_PER_BIT * capacity_mb * 8 * MB / 1e6,
        banks=banks,
    )


def sot_array(capacity_mb: float, optimized: bool = False) -> ArrayPPA:
    s = _sqrt_scale(capacity_mb)
    # Density advantage -> more banks at iso-capacity; the DTCO additionally
    # "individually optimizes banks with various bandwidths and capacities"
    # (paper contribution 2), shrinking the bank granularity to 1 MB.
    banks = max(1, int(capacity_mb // (1 if optimized else 2)))
    if optimized:
        t0r, tgr, t0w, tgw = (
            _SOT_OPT_T0_RD_NS,
            _SOT_OPT_TG_RD_NS,
            _SOT_OPT_T0_WR_NS,
            _SOT_OPT_TG_WR_NS,
        )
        er, ew = _SOT_OPT_E_RD_PJ_2MB, _SOT_OPT_E_WR_PJ_2MB
        area_bit = _SOT_OPT_AREA_UM2_PER_BIT
        name = "sot_opt"
    else:
        t0r, tgr, t0w, tgw = (
            _SOT_T0_RD_NS,
            _SOT_TG_RD_NS,
            _SOT_T0_WR_NS,
            _SOT_TG_WR_NS,
        )
        er, ew = _SOT_E_RD_PJ_2MB, _SOT_E_WR_PJ_2MB
        area_bit = _SOT_AREA_UM2_PER_BIT
        name = "sot"
    return ArrayPPA(
        technology=name,
        capacity_mb=capacity_mb,
        read_latency_ns=t0r + tgr * s,
        write_latency_ns=t0w + tgw * s,
        read_energy_pj_per_access=er * (1 + 0.35 * (s - 1)),
        write_energy_pj_per_access=ew * (1 + 0.35 * (s - 1)),
        leakage_w=_SOT_LEAK_W_PER_MB * capacity_mb,
        area_mm2=area_bit * capacity_mb * 8 * MB / 1e6,
        banks=banks,
    )


def device_array_terms(
    dev: SOTDevice,
    capacity_mb: float,
    tg_rd_ns: float = _SOT_OPT_TG_RD_NS,
    tg_wr_ns: float = _SOT_OPT_TG_WR_NS,
    energy_cap_slope: float = 0.35,
) -> tuple[float, float, float, float]:
    """DTCO-device array terms: (t_rd_ns, t_wr_ns, e_rd_pj, e_wr_pj).

    Array latency = cell access + interconnect growth; a 256 B access
    touches 2048 bitcells, with an 8 pJ periphery floor.  The single source
    for both :func:`sot_array_from_device` and device-carrying
    ``repro.spec.MemTechSpec`` builds — change it in one place.
    """
    cell = bitcell_ppa(dev)
    s = _sqrt_scale(capacity_mb)
    t_rd = cell.read_latency_s * 1e9 + tg_rd_ns * s
    t_wr = cell.write_latency_s * 1e9 + tg_wr_ns * s
    e_rd = cell.read_energy_j * 2048 * 1e12 * 0.35 + 8.0
    e_wr = cell.write_energy_j * 2048 * 1e12 * 0.35 + 8.0
    scale = 1 + energy_cap_slope * (s - 1)
    return t_rd, t_wr, e_rd * scale, e_wr * scale


def sot_array_from_device(capacity_mb: float, dev: SOTDevice) -> ArrayPPA:
    """Build the array model from an explicit DTCO device point."""
    base = sot_array(capacity_mb, optimized=True)
    t_rd, t_wr, e_rd, e_wr = device_array_terms(dev, capacity_mb)
    return dataclasses.replace(
        base,
        read_latency_ns=t_rd,
        write_latency_ns=t_wr,
        read_energy_pj_per_access=e_rd,
        write_energy_pj_per_access=e_wr,
        spec_name="sot_dtco_device",  # bespoke point, not a registered spec
    )


def glb_array(technology: str, capacity_mb: float) -> ArrayPPA:
    """Array PPA of any *registered* technology (see ``repro.spec``).

    Unknown names raise ``repro.spec.UnknownTechnologyError`` — a
    ``ValueError`` subclass carrying near-miss suggestions.
    """
    from repro.spec import get_tech

    return get_tech(technology).build(capacity_mb)


# ---------------------------------------------------------------------------
# Off-chip DRAM (HBM3) and the full hybrid system
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DRAMModel:
    """HBM3 stack."""

    energy_pj_per_byte: float = 2.0  # HBM3 on-package access energy
    access_latency_ns: float = 110.0
    bandwidth_gb_s: float = 819.0
    access_bytes: int = 64

    def energy_pj_per_access(self) -> float:
        return self.energy_pj_per_byte * self.access_bytes


@dataclasses.dataclass(frozen=True)
class HybridMemorySystem:
    """HBM3 + GLB (SRAM or SOT) + small double-buffered SRAM (paper Fig. 5)."""

    glb: ArrayPPA
    dram: DRAMModel = DRAMModel()
    # double-buffered weight SRAM: small, fixed
    weight_buffer_mb: float = 2.0

    @property
    def weight_buffer(self) -> ArrayPPA:
        return sram_array(self.weight_buffer_mb)
