"""System-level PPA evaluation (paper Section V-E, Figs. 9-12, 18, 19).

Combines the Algorithm-1/2 access counts with the array-level models.
Per the paper: "This analysis only incorporates the PPA metrics from the
memory system (DRAM and GLB), assuming that the PPA of the compute unit is
constant" — so the reported **latency is memory-system latency**:

  latency = T_dram + T_glb
  T_dram  = dram_bytes / HBM3_BW          (bursts pipelined/prefetched; the
                                           double-buffered SRAM hides access
                                           latency behind compute, III-B)
  T_glb   = accesses * t_access / banks   (bank-level parallelism; the DTCO
                                           lets SOT banks be smaller/more
                                           numerous — "memory banks are
                                           individually optimized")

Energy = DRAM dynamic + GLB dynamic + GLB leakage * runtime, where runtime
is max(compute time, memory latency) — leakage burns for the whole run,
which is why the paper finds >50% of the energy savings come from
SOT-MRAM's near-zero leakage.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.access_counts import AccessCounts, MemoryParams, access_counts
from repro.core.bandwidth import ArrayConfig
from repro.core.memory_system import DRAMModel, HybridMemorySystem, glb_array
from repro.core.workload import Workload


@dataclasses.dataclass(frozen=True)
class SystemMetrics:
    energy_j: float
    latency_s: float  # memory-system latency (the paper's reported metric)
    runtime_s: float  # max(compute, memory) — leakage accumulation window
    dram_energy_j: float
    glb_energy_j: float
    leakage_energy_j: float
    dram_latency_s: float
    glb_latency_s: float
    compute_time_s: float
    counts: AccessCounts


def evaluate_system(
    workload: Workload,
    batch: int,
    system: HybridMemorySystem,
    mode: str = "inference",
    d_w: int = 4,
    arr: ArrayConfig | None = None,
    mem_params: MemoryParams | None = None,
) -> SystemMetrics:
    """Closed-form system PPA of one design point.

    The batched path in ``repro.dse.grid`` mirrors these formulas
    operand-for-operand over whole capacity/technology grids, so the two
    stay bit-compatible (tests/test_dse_equivalence.py) — change them in
    lockstep.
    """
    arr = arr or ArrayConfig()
    mem = mem_params or MemoryParams(glb_mb=system.glb.capacity_mb)
    counts = access_counts(workload, batch, mem, mode, d_w)

    dram, glb = system.dram, system.glb
    e_dram = counts.dram_total * dram.energy_pj_per_access() * 1e-12
    e_glb = (
        counts.rd_glb * glb.read_energy_pj_per_access
        + counts.wr_glb * glb.write_energy_pj_per_access
    ) * 1e-12

    # --- memory-system latency ---
    # Weight streaming is latency-hidden behind compute by the
    # double-buffered SRAM (Section III-B); only activation/gradient DRAM
    # traffic exposes latency.
    exposed_bytes = counts.dram_exposed * dram.access_bytes
    hidden_bytes = counts.dram_hidden * dram.access_bytes
    t_dram = exposed_bytes / (dram.bandwidth_gb_s * 1e9)
    t_glb = (
        counts.rd_glb * glb.read_latency_ns + counts.wr_glb * glb.write_latency_ns
    ) * 1e-9 / glb.banks
    latency = t_dram + t_glb

    # --- compute-time floor (training ~3x forward MACs: fwd + 2 bwd GEMMs) ---
    mac_mult = 3.0 if mode == "training" else 1.0
    t_compute = mac_mult * workload.total_macs(batch) / arr.peak_ops_per_sec
    t_weight_stream = hidden_bytes / (dram.bandwidth_gb_s * 1e9)
    runtime = max(t_compute, t_weight_stream, latency)

    e_leak = glb.leakage_w * runtime
    return SystemMetrics(
        energy_j=e_dram + e_glb + e_leak,
        latency_s=latency,
        runtime_s=runtime,
        dram_energy_j=e_dram,
        glb_energy_j=e_glb,
        leakage_energy_j=e_leak,
        dram_latency_s=t_dram,
        glb_latency_s=t_glb,
        compute_time_s=t_compute,
        counts=counts,
    )


def compare_technologies(
    workload: Workload,
    batch: int,
    capacity_mb: float,
    mode: str,
    d_w: int = 4,
    arr: ArrayConfig | None = None,
    technologies: tuple[str, ...] | None = None,
) -> dict[str, SystemMetrics]:
    """Registered technologies at iso-capacity (Fig. 18).

    ``technologies=None`` resolves to the registry's ``"paper"`` group
    (SRAM vs SOT vs DTCO-opt SOT); any registered name is accepted.
    """
    from repro.spec import tech_group

    out = {}
    for tech in technologies or tech_group("paper"):
        system = HybridMemorySystem(glb=glb_array(tech, capacity_mb))
        out[tech] = evaluate_system(workload, batch, system, mode, d_w, arr)
    return out


def fig18_ratio_keys(
    technologies: tuple[str, ...] | None = None, baseline: str | None = None
) -> tuple[str, ...]:
    """The Fig. 18 ratio keys: ``{tech}_{energy,latency}_x`` for every
    non-baseline technology, registry-derived by default."""
    from repro.spec import BASELINE_TECH, tech_group

    baseline = baseline or BASELINE_TECH
    techs = technologies or tech_group("paper")
    return tuple(
        f"{tech}_{metric}_x"
        for tech in techs
        if tech != baseline
        for metric in ("energy", "latency")
    )


def improvement_ratios(
    m: dict[str, SystemMetrics], baseline: str | None = None
) -> dict[str, float]:
    """Fig. 18 ratio keys from a {technology: SystemMetrics} mapping.

    Ratios are generated for every non-baseline technology in ``m`` (in
    its insertion order) against ``baseline`` (default: the registry's
    baseline technology, SRAM).
    """
    from repro.spec import BASELINE_TECH

    baseline = baseline or BASELINE_TECH
    if baseline not in m:
        raise KeyError(
            f"baseline technology {baseline!r} missing from metrics {sorted(m)}"
        )
    base = m[baseline]
    out: dict[str, float] = {}
    for tech, metrics in m.items():
        if tech == baseline:
            continue
        out[f"{tech}_energy_x"] = base.energy_j / metrics.energy_j
        out[f"{tech}_latency_x"] = base.latency_s / metrics.latency_s
    return out


def improvement_table(
    workloads: dict[str, Workload],
    batch: int,
    capacity_mb: float,
    mode: str,
    d_w: int = 4,
    technologies: tuple[str, ...] | None = None,
    baseline: str | None = None,
) -> dict[str, dict[str, float]]:
    """Energy/latency improvement over the baseline technology per model."""
    return {
        name: improvement_ratios(
            compare_technologies(
                wl, batch, capacity_mb, mode, d_w, technologies=technologies
            ),
            baseline=baseline,
        )
        for name, wl in workloads.items()
    }


def geomean(vals) -> float:
    vals = list(vals)
    return math.exp(sum(math.log(max(v, 1e-30)) for v in vals) / len(vals))
