"""TPU adaptation of the paper's GLB co-design: VMEM tile / remat planning.

The paper sizes an on-chip GLB so the working set of each layer (+ training
state) stays on-chip, and widens memory buses to meet the OI-derived
bandwidth demand.  On TPU the corresponding knobs are:

  * Pallas ``BlockSpec`` tile shapes — the per-kernel "GLB allocation" out
    of VMEM.  ``plan_matmul_tiles`` maximises operational intensity
    (paper Eq. 1/6 applied to the HBM<->VMEM interface) subject to the VMEM
    capacity constraint and MXU alignment (multiples of 128).
  * The activation-checkpoint (remat) policy — the training analogue of
    Algorithm 2's "does the cumulative working set fit?" test.

Hardware constants follow the brief: 197 TFLOP/s bf16, 819 GB/s HBM.
"""

from __future__ import annotations

import dataclasses
import math

# Per-core VMEM budget we allow kernels to claim (v5e-class part; leave
# headroom for double buffering which pallas pipelining allocates 2x).
VMEM_BYTES = 64 * 1024 * 1024
MXU_ALIGN = 128
PEAK_FLOPS = 197e12
HBM_BW = 819e9


@dataclasses.dataclass(frozen=True)
class MatmulTiling:
    bm: int
    bk: int
    bn: int
    vmem_bytes: int
    oi_flops_per_byte: float
    hbm_bytes: float
    flops: float

    @property
    def compute_bound(self) -> bool:
        return self.oi_flops_per_byte >= PEAK_FLOPS / HBM_BW  # ridge ~240


def _align_down(x: int, a: int = MXU_ALIGN) -> int:
    return max(a, (x // a) * a)


def matmul_tile_cost(m: int, k: int, n: int, bm: int, bk: int, bn: int, d_w: int):
    """HBM traffic + working set for a (bm,bk,bn)-tiled (m,k,n) matmul.

    Per output tile (bm x bn): stream A-rows (bm*k) and B-cols (k*bn) once
    each; with k-loop accumulation in VMEM only the final tile writes out.
    """
    grid_m, grid_n = math.ceil(m / bm), math.ceil(n / bn)
    a_bytes = grid_n * m * k * d_w  # A re-read once per column of tiles
    b_bytes = grid_m * k * n * d_w  # B re-read once per row of tiles
    o_bytes = m * n * d_w
    hbm = a_bytes + b_bytes + o_bytes
    vmem = (bm * bk + bk * bn + bm * bn) * d_w * 2  # x2 double buffering
    flops = 2.0 * m * k * n
    return hbm, vmem, flops


def plan_matmul_tiles(
    m: int, k: int, n: int, d_w: int = 2, vmem_budget: int = VMEM_BYTES
) -> MatmulTiling:
    """Pick MXU-aligned (bm, bk, bn) maximising OI under the VMEM budget.

    Mirrors the paper's DTCO loop: enumerate design points, keep feasible
    ones (capacity constraint = GLB sizing), maximise OI (bandwidth
    constraint = bus sizing)."""
    best: MatmulTiling | None = None
    candidates = [128, 256, 512, 1024, 2048]
    for bm in candidates:
        if bm > max(m, 128) * 2:
            continue
        for bn in candidates:
            if bn > max(n, 128) * 2:
                continue
            for bk in candidates:
                if bk > max(k, 128) * 2:
                    continue
                bm_, bk_, bn_ = (
                    _align_down(min(bm, m)),
                    _align_down(min(bk, k)),
                    _align_down(min(bn, n)),
                )
                hbm, vmem, flops = matmul_tile_cost(m, k, n, bm_, bk_, bn_, d_w)
                if vmem > vmem_budget:
                    continue
                t = MatmulTiling(
                    bm=bm_,
                    bk=bk_,
                    bn=bn_,
                    vmem_bytes=vmem,
                    oi_flops_per_byte=flops / hbm,
                    hbm_bytes=hbm,
                    flops=flops,
                )
                if best is None or t.oi_flops_per_byte > best.oi_flops_per_byte or (
                    t.oi_flops_per_byte == best.oi_flops_per_byte
                    and t.vmem_bytes < best.vmem_bytes
                ):
                    best = t
    assert best is not None
    return best


def plan_attention_tiles(
    seq_q: int,
    seq_kv: int,
    head_dim: int,
    d_w: int = 2,
    vmem_budget: int = VMEM_BYTES,
) -> tuple[int, int]:
    """(block_q, block_kv) for blockwise attention under the VMEM budget."""
    best = (MXU_ALIGN, MXU_ALIGN)
    for bq in (128, 256, 512, 1024):
        for bkv in (128, 256, 512, 1024, 2048):
            if bq > seq_q or bkv > seq_kv:
                continue
            # working set: Q-tile, K/V-tiles, score tile, accumulators (x2
            # pipeline buffering)
            ws = (bq * head_dim * 2 + bkv * head_dim * 2 + bq * bkv) * d_w * 2
            if ws <= vmem_budget and bq * bkv >= best[0] * best[1]:
                best = (bq, bkv)
    return best


# ---------------------------------------------------------------------------
# Remat planning — Algorithm 2's residency test, applied to HBM
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RematPlan:
    policy: str  # "none" | "dots" | "full"
    activation_bytes_no_remat: float
    activation_bytes_chosen: float
    hbm_budget_bytes: float


def plan_remat(
    n_layers: int,
    tokens_per_device: int,
    d_model: int,
    d_ff_factor: float = 4.0,
    d_w: int = 2,
    hbm_bytes: float = 16e9,
    params_plus_opt_bytes: float = 0.0,
    headroom: float = 0.8,
) -> RematPlan:
    """Choose the checkpoint policy the way Algorithm 2 chooses GLB traffic:
    if activations for all layers fit -> no remat ("algorithmic minimum");
    if only per-layer boundaries fit -> full remat; else save dot outputs.
    """
    per_layer = tokens_per_device * d_model * (2 + 2 * d_ff_factor) * d_w
    full = n_layers * per_layer
    boundaries = n_layers * tokens_per_device * d_model * d_w
    dots = n_layers * tokens_per_device * d_model * (1 + d_ff_factor / 2) * d_w
    budget = hbm_bytes * headroom - params_plus_opt_bytes
    if full <= budget:
        return RematPlan("none", full, full, budget)
    if dots <= budget:
        return RematPlan("dots", full, dots, budget)
    return RematPlan("full", full, boundaries, budget)
